"""Streaming frame driver: serve-style batching for compiled networks.

Mirrors the LM serving engine's admission discipline on the bayesnet side:
frames are submitted at any time into a pending queue, and every ``step``
packs up to ``max_batch`` of them, runs the compiled program once, and
returns per-request posteriors.  Launch shapes are drawn from a small ladder
of power-of-two *buckets* (1, 2, 4, ... max_batch): a short batch pads up to
the nearest bucket by repeating its last real frame instead of always paying
the full ``max_batch`` lanes, so a 1-frame step on a 1024-lane driver costs
one frame's entropy, not ~1024x.  Padded lanes are dropped at harvest; each
bucket compiles once and is reused for every launch of that shape.

With the fused independent-entropy default (``compile_network``'s production
mode) every frame in a launch carries its own joint sample, so batch-mates
never share errors.  The driver also sequences launch keys itself: pass
``key=None`` to ``step`` / ``drain`` and each launch folds a monotonically
increasing launch counter into the driver's base key, so successive launches
draw disjoint entropy without the caller threading PRNG state.

**Async mode.**  ``step(block=False)`` dispatches the launch and returns
immediately with its ticket: jax's async dispatch runs the device work while
the driver packs and dispatches the next batch, and nothing calls
``block_until_ready`` until ``harvest()`` converts the posteriors to host
arrays.  ``drain_async`` pipelines the whole queue this way -- every launch
in flight back-to-back, one synchronisation at the end.  The launch-counter
key sequencing makes this safe: tickets are assigned at dispatch in
submission order, so async results map to rids exactly as sync results do,
and a sync and an async driver with the same ``(base_key, salt)`` return
bit-identical posteriors.

Every driver additionally folds a ``salt`` into its base key.  ``salt=None``
(the default) takes the next value of a process-wide driver counter, so two
drivers constructed with defaults -- the footgun the old ``PRNGKey(0)``
default base key armed -- no longer draw bit-identical joint samples per
launch index.  Pass an explicit ``salt`` (a driver id) to make a driver's key
sequence reproducible across processes/restarts: drivers with the same
``(base_key, salt)`` replay the same launches, drivers differing in either
draw disjoint entropy.

**Confidence-gated retry.**  ``retry=RetryPolicy(...)`` makes reliability a
measured, acted-on property: every harvested frame gets a decision-margin
confidence (:func:`~repro.bayesnet.reliability.decision_confidence`), and
frames below ``min_confidence`` are re-queued for a fresh launch -- new
entropy via the launch counter, ``escalation``-times longer bitstream per
attempt (escalated programs compile lazily, once per attempt level, and are
cached like buckets).  After ``max_retries`` the frame is emitted anyway with
``reliable=False`` -- graceful degradation, never a dropped frame.  Results
keep the legacy ``{rid: (post, accepted)}`` shape; per-frame verdicts land in
``driver.reports[rid]`` (:class:`~repro.bayesnet.reliability.FrameReport`)
and aggregates in ``driver.stats``
(:class:`~repro.bayesnet.reliability.ReliabilityStats`).  With retry enabled
a ``step`` may dispatch several launches (one per pending attempt level plus
the main batch); an explicit ``key`` is folded with the launch index within
the step.  ``retry=None`` (default) is behaviour-identical to the
pre-reliability driver.

**Launch watchdog.**  Every dispatch's wall time feeds a
:class:`~repro.distributed.fault.StragglerWatch` EWMA (the train-loop
straggler detector, reused verbatim): dispatches slower than ``threshold x``
the running mean -- a recompile for a new bucket shape, a contended device,
host-side stalls -- are counted in ``stats.slow_launches``.  Under async
dispatch the wall time covers trace/compile + enqueue, which is exactly the
host-side latency a serving deployment cares about.

**Telemetry.**  ``trace=Tracer()`` / ``metrics=MetricsRegistry()``
(:mod:`repro.obs`) light up the whole serving path with zero behaviour
change -- the traced driver's posteriors are bit-identical to the untraced
one's (a regression-tested property, like the <=5% overhead bound).  Each
launch becomes a span tree honouring jax's async dispatch: a ``launch[n]``
parent span from dispatch to harvest, ``pack`` and ``dispatch`` sync child
spans for the host-side work, a ``device`` child opened when the dispatch
call returns and closed only when :meth:`harvest` first blocks on the result
(overlapping ``device`` spans in the exported trace ARE the async pipeline),
and a ``harvest`` child for host-side conversion + confidence gating.
Retried frames get ``retry[rid]`` spans nested under the launch that flagged
them, covering the wait until their re-launch's verdict.  The registry
counts frames in/out, launches, per-bucket launch shapes, padded lanes,
retry attempts per rung, flagged-unreliable emissions, escalated-plan cache
hits/misses, and entropy words generated, and feeds ``frame_ms`` (enqueue ->
emit, annotated with the paper's 0.4 ms budget) and ``launch_ms``
(dispatch -> harvest) histograms; the watchdog writes into the same registry.
``trace=None`` (default) leaves every hot path untouched.

**Fault tolerance.**  ``fault=LaunchFaultInjector(...)`` threads seeded chaos
through the launch path (dropped launches, stalled dispatches, corrupted
harvest buffers), and :meth:`harvest` is all-or-nothing *per launch* either
way: every harvested buffer is validated (finite posteriors, non-negative
accepted counts), and any exception while processing one launch -- injected
or organic -- recovers instead of stranding the fleet.  Recovery closes the
launch's spans, records a :class:`LaunchFailure` (``driver.launch_failures``,
``stats.launch_failures``), and re-enqueues the launch's frames at the front
of their queue so the next ``step`` re-dispatches them with *fresh entropy*
(the launch counter advanced, so a re-launch never replays the failed draw).
A frame that fails ``max_redispatch`` launches is emitted with a zero
posterior, ``accepted=0`` and a ``reliable=False`` report -- the never-drop
invariant extends to failing hardware: every submitted frame terminates.
``fault=None`` with healthy buffers is bit-identical to the pre-fault driver.

**Drift monitoring + hot-swap.**  ``drift=DriftMonitor(...)`` feeds every
harvested launch's mean decision confidence and accept-rate into the
monitor's CUSUM detectors (:mod:`repro.bayesnet.reliability`), so a driver
notices its own crossbar aging without an oracle in the loop.  The
complementary actuator is :meth:`swap_net`: replace the compiled program
*between launches* -- typically with a recalibrated twin from
:mod:`repro.bayesnet.calibrate` -- without dropping or reordering a single
frame.  Every in-flight launch harvests against the plan it dispatched with
(device buffers and the stream length are snapshotted per launch at
dispatch), queued frames simply ride the next launch on the new plan, and
the launch counter keeps advancing so entropy stays disjoint across the
swap.  ``drift=None`` (default) costs nothing on the hot path.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.bayesnet.compile import CompiledNetwork, compile_network
from repro.bayesnet.reliability import (
    DriftMonitor,
    FrameReport,
    ReliabilityStats,
    RetryPolicy,
    decision_confidence,
)
from repro.distributed.fault import LaunchFault, LaunchFaultInjector, StragglerWatch
from repro.obs import PAPER_BUDGET_MS, MetricsRegistry, Tracer

# Process-wide source of default driver salts (one per construction).
_DRIVER_IDS = itertools.count()


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unharvested launch (dispatch order preserved)."""

    ticket: int
    taken: list                      # (rid, row, attempt, bits_before) tuples
    attempt: int
    post: object                     # device posteriors (None for a dropped launch)
    accepted: object                 # device accepted counts (None when dropped)
    lspan: Optional[int]             # launch span id
    dspan: Optional[int]             # device span id
    t_dispatch: Optional[float]      # dispatch wall-clock
    n_bits: int                      # stream length of the plan that dispatched
    fault: Optional[str] = None      # injected fault kind, if any
    hspan: Optional[int] = None      # harvest span id (opened at harvest)


@dataclasses.dataclass(frozen=True)
class LaunchFailure:
    """One failed launch, as recorded by :meth:`FrameDriver.harvest`.

    ``kind`` is the injected fault kind when the failure was injected, else
    the :class:`~repro.distributed.fault.LaunchFault` kind (``"invalid"`` for
    organically corrupted buffers) or ``"error"`` for any other exception.
    ``rids`` are the frames that rode the launch (re-enqueued or flagged by
    the recovery path, never dropped).
    """

    ticket: int
    kind: str
    rids: Tuple[int, ...]
    attempt: int
    error: str


class FrameDriver:
    def __init__(
        self,
        net: CompiledNetwork,
        max_batch: int = 256,
        base_key: jax.Array | None = None,
        salt: int | None = None,
        retry: RetryPolicy | None = None,
        watchdog: StragglerWatch | None = None,
        trace: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
        fault: LaunchFaultInjector | None = None,
        max_redispatch: int = 3,
        drift: DriftMonitor | None = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if retry is not None and not isinstance(retry, RetryPolicy):
            raise TypeError(f"retry must be a RetryPolicy or None, got {type(retry)!r}")
        if max_redispatch < 0:
            raise ValueError(f"max_redispatch must be >= 0, got {max_redispatch}")
        if drift is not None and not isinstance(drift, DriftMonitor):
            raise TypeError(f"drift must be a DriftMonitor or None, got {type(drift)!r}")
        self.net = net
        self.max_batch = int(max_batch)
        self.retry = retry
        self.fault = fault
        self.drift = drift
        self.max_redispatch = int(max_redispatch)
        self.launch_failures: List[LaunchFailure] = []
        self._fail_counts: Dict[int, int] = {}   # rid -> failed launches so far
        self._queue: deque = deque()
        self._next_rid = 0
        self.salt = next(_DRIVER_IDS) if salt is None else int(salt)
        base = base_key if base_key is not None else jax.random.PRNGKey(0)
        self._base_key = jax.random.fold_in(base, self.salt)
        self._launches = 0
        self._dispatches = 0
        self._inflight: deque[_InFlight] = deque()   # in dispatch order
        self.last_launch_shape: Optional[Tuple[int, int]] = None
        # --- telemetry (inert when both are None) ---
        self.trace = trace
        if metrics is None and trace is not None:
            metrics = MetricsRegistry()   # spans without counters are half a story
        self.metrics = metrics
        self._t_submit: Dict[int, float] = {}     # rid -> enqueue wall-clock
        self._retry_spans: Dict[int, int] = {}    # rid -> open retry span id
        # --- reliability layer (inert when retry is None) ---
        self._nets: Dict[int, CompiledNetwork] = {0: net}
        self._retry_q: deque = deque()   # (rid, row, attempt, bits_before)
        self.reports: Dict[int, FrameReport] = {}
        self.stats = ReliabilityStats()
        self.watch = (
            watchdog if watchdog is not None else StragglerWatch(metrics=metrics)
        )

    # ------------------------------------------------------------- admission
    def submit(self, frames) -> List[int]:
        """Queue evidence frames ((n_ev,) each, or an (N, n_ev) array); returns rids."""
        frames = np.asarray(frames, np.int32)
        if frames.ndim == 1:
            frames = frames[None, :]
        assert frames.shape[1] == len(self.net.evidence), frames.shape
        rids = []
        for row in frames:
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append((rid, row))
            rids.append(rid)
        if self.metrics is not None:
            now = time.perf_counter()
            for rid in rids:
                self._t_submit[rid] = now
            self.metrics.inc("frames_in", len(rids))
            self.metrics.set_gauge("pending", len(self._queue))
        if self.trace is not None:
            self.trace.event("submit", n=len(rids))
        return rids

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def pending_retries(self) -> int:
        """Frames awaiting a confidence-gated re-launch."""
        return len(self._retry_q)

    @property
    def in_flight(self) -> int:
        """Dispatched launches whose results have not been harvested yet."""
        return len(self._inflight)

    @property
    def launches(self) -> int:
        """Launches dispatched so far -- doubles as the crossbar cycle estimate."""
        return self._launches

    # -------------------------------------------------------------- hot-swap
    def swap_net(self, net: CompiledNetwork) -> None:
        """Replace the compiled program between launches -- zero frame loss.

        The recalibration actuator: swap in a re-lowered twin of the current
        network (same evidence columns, same query layout; typically
        :func:`repro.bayesnet.calibrate.recalibrated_network`) while the
        driver keeps serving.  Ordering guarantees:

        * every **in-flight** launch harvests against the plan it dispatched
          with -- its device buffers and stream length were snapshotted into
          the launch record at dispatch, so posteriors of pre-swap launches
          are bit-identical to a never-swapped driver's;
        * **queued** frames (main or retry) simply ride the next launch on
          the new plan, in their original order -- nothing is dropped,
          re-ordered, or re-keyed;
        * the launch counter keeps advancing, so post-swap launches draw
          entropy disjoint from every pre-swap launch.

        Escalated retry programs are recompiled lazily against the new
        network (the per-attempt cache is reset).
        """
        if not isinstance(net, CompiledNetwork):
            raise TypeError(f"swap_net needs a CompiledNetwork, got {type(net)!r}")
        if tuple(net.evidence) != tuple(self.net.evidence):
            raise ValueError(
                f"swap_net evidence mismatch: {net.evidence} != {self.net.evidence}"
            )
        if tuple(net.query_cards) != tuple(self.net.query_cards):
            raise ValueError(
                "swap_net query layout mismatch: "
                f"{net.query_cards} != {self.net.query_cards}"
            )
        self.net = net
        self._nets = {0: net}
        if self.metrics is not None:
            self.metrics.inc("net_swaps")
        if self.trace is not None:
            self.trace.event("swap_net", n_bits=net.n_bits)

    # ----------------------------------------------------------------- serve
    def _next_key(self) -> jax.Array:
        key = jax.random.fold_in(self._base_key, self._launches)
        self._launches += 1
        return key

    def _bucket(self, n_real: int) -> int:
        """Smallest power-of-two launch shape >= n_real (capped at max_batch).

        Padding to a bucket instead of to ``max_batch`` is the tail fix: the
        padded lanes still replicate the last real frame (one static shape
        per bucket), but a nearly-empty step skips the entropy planes of
        every lane above its bucket because those lanes are simply not in
        the launch.
        """
        b = 1
        while b < n_real:
            b <<= 1
        return min(b, self.max_batch)

    def _net_for(self, attempt: int) -> CompiledNetwork:
        """The (lazily compiled, cached) program for one retry attempt level.

        Attempt ``a`` runs ``escalation^a x`` the base stream length, capped
        at the policy's ``max_n_bits``; the escalated program reuses the base
        network's full lowering configuration (queries, evidence, estimator,
        entropy mode, noise model) on a single device -- retry batches are
        short tails, not the place for shard_map.
        """
        cached = attempt in self._nets
        if self.metrics is not None:
            self.metrics.inc("plan_cache_hits" if cached else "plan_cache_misses")
        if not cached:
            assert self.retry is not None
            n_bits = self.retry.n_bits_for(self.net.n_bits, attempt)
            self._nets[attempt] = compile_network(
                self.net.spec, n_bits, self.net.queries, self.net.evidence,
                share_entropy=self.net.share_entropy,
                estimator=self.net.estimator, fused=self.net.fused,
                noise=self.net.noise, devices=1, trace=self.trace,
                drift_epochs=self.net.drift_epochs, program=self.net.program,
            )
        return self._nets[attempt]

    def _pack(self, taken: list) -> Tuple[np.ndarray, int]:
        """Stack the taken frames and pad up to their power-of-two bucket."""
        ev = np.stack([row for _, row, _, _ in taken])
        n_real = ev.shape[0]
        bucket = self._bucket(n_real)
        if n_real < bucket:
            pad = np.repeat(ev[-1:], bucket - n_real, axis=0)
            ev = np.concatenate([ev, pad], axis=0)
        return ev, n_real

    def _launch(self, key: jax.Array | None, taken: list, attempt: int) -> int:
        """Pack one batch at one attempt level, launch it, park the results."""
        tr, mx = self.trace, self.metrics
        lspan = dspan = t_dispatch = None
        if tr is not None:
            lspan = tr.begin(
                f"launch[{self._dispatches}]", track="launch",
                attempt=attempt, n_real=len(taken),
            )
        if key is None:
            key = self._next_key()
        if tr is not None:
            with tr.span("pack", parent=lspan):
                ev, n_real = self._pack(taken)
        else:
            ev, n_real = self._pack(taken)
        self.last_launch_shape = ev.shape
        net = self.net if attempt == 0 else self._net_for(attempt)
        injected = (
            self.fault.draw(self.salt, self._dispatches)
            if self.fault is not None else None
        )
        if mx is not None:
            t_dispatch = time.perf_counter()
        self.watch.step_start()
        if injected == "stall":
            # host-side latency sized to trip the StragglerWatch threshold;
            # the launch itself still runs and harvests normally
            time.sleep(self.fault.stall_ms / 1e3)
        if injected == "drop":
            # the launch never runs: nothing is enqueued, harvest finds no
            # result and routes the frames through the recovery path
            post = accepted = None
        elif tr is not None:
            # host-side dispatch only: under async dispatch net.run returns
            # as soon as the work is enqueued, so this span is trace/compile
            # lookup + enqueue -- the device interval is the `device` span
            with tr.span("dispatch", parent=lspan, bucket=ev.shape[0]):
                post, accepted = net.run(key, ev)
        else:
            post, accepted = net.run(key, ev)
        ticket = self._dispatches
        self._dispatches += 1
        if self.watch.step_end(ticket):
            self.stats.slow_launches += 1
        self.stats.launches += 1
        if tr is not None:
            dspan = tr.begin("device", parent=lspan, track="device", ticket=ticket)
        if mx is not None:
            mx.inc("launches")
            mx.inc(f"bucket_{ev.shape[0]}")
            mx.inc("padded_lanes", ev.shape[0] - n_real)
            if injected is not None:
                mx.inc(f"fault_injected_{injected}")
            if post is not None:
                mx.inc(
                    "entropy_words",
                    ev.shape[0] * (net.n_bits // 32) * net.spec.n_nodes,
                )
            if attempt > 0:
                mx.inc(f"retry_launches_attempt_{attempt}")
            mx.set_gauge("in_flight", len(self._inflight) + 1)
            mx.set_gauge("pending", len(self._queue))
        self._inflight.append(
            _InFlight(ticket, taken, attempt, post, accepted, lspan, dspan,
                      t_dispatch, net.n_bits, fault=injected)
        )
        return ticket

    def _dispatch(self, key: jax.Array | None) -> int:
        """Pack one main-queue batch (attempt 0), launch it (async)."""
        taken = [
            (rid, row, 0, 0)
            for rid, row in (
                self._queue.popleft()
                for _ in range(min(self.max_batch, len(self._queue)))
            )
        ]
        return self._launch(key, taken, 0)

    def _dispatch_retries(self, key: jax.Array | None) -> int:
        """Launch one batch from the retry queue (head's attempt level)."""
        attempt = self._retry_q[0][2]
        taken, rest = [], deque()
        while self._retry_q:
            item = self._retry_q.popleft()
            if item[2] == attempt and len(taken) < self.max_batch:
                taken.append(item)
            else:
                rest.append(item)
        self._retry_q = rest
        return self._launch(key, taken, attempt)

    def harvest(self) -> Dict[int, Tuple[np.ndarray, int]]:
        """Block on every in-flight launch and return {rid: (post, accepted)}.

        The single synchronisation point of the async mode: device arrays are
        converted to host arrays here (masking the padded lanes out -- only
        real rids appear), in dispatch order, so result mapping follows
        submission order exactly as in the sync path.  With a retry policy,
        under-confidence frames with budget left are re-queued instead of
        returned (dispatch them with the next ``step``/``drain``); emitted
        frames additionally gain a ``reports[rid]`` entry and roll into
        ``stats``.

        **All-or-nothing per launch.**  Harvested buffers are validated
        (finite posteriors, non-negative accepted counts) and any exception
        while converting or gating one launch is caught *per launch*: the
        failed launch's frames are re-enqueued at the front of their queue
        (main or retry, original order preserved, re-dispatched with fresh
        entropy next ``step``) or -- past ``max_redispatch`` failed launches
        -- emitted with a zero posterior and ``reliable=False``; rid maps,
        submit timestamps and span state are restored either way, and the
        remaining in-flight launches harvest normally.  A raise mid-harvest
        can no longer strand the fleet.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._inflight:
            lf = self._inflight.popleft()
            try:
                self._harvest_one(lf, out)
            except Exception as exc:   # noqa: BLE001 -- per-launch recovery
                self._recover_launch(lf, exc, out)
        return out

    def _harvest_one(self, lf: _InFlight, out: Dict[int, Tuple[np.ndarray, int]]):
        """Convert, validate, and emit one launch (raises on a bad launch)."""
        tr, mx = self.trace, self.metrics
        taken, attempt = lf.taken, lf.attempt
        if tr is not None:
            lf.hspan = tr.begin("harvest", parent=lf.lspan, ticket=lf.ticket)
        if lf.post is None:
            # dropped launch: nothing was ever enqueued
            raise LaunchFault("drop", lf.ticket, "launch produced no result")
        post, accepted = np.asarray(lf.post), np.asarray(lf.accepted)
        if tr is not None:
            # first observable point at which this launch's device work
            # is complete: the host just blocked on its arrays
            tr.end(lf.dspan)
        if lf.fault == "corrupt":
            # injected buffer corruption: validation below must catch it
            post = np.full_like(post, np.nan)
        if not np.all(np.isfinite(post)):
            raise LaunchFault("invalid", lf.ticket, "non-finite posterior buffer")
        if np.any(accepted < 0):
            raise LaunchFault("invalid", lf.ticket, "negative accepted count")
        t_now = time.perf_counter() if mx is not None else None
        emitted: List[int] = []
        n_real = len(taken)
        n_bits = lf.n_bits   # snapshot from dispatch: immune to swap_net
        conf = None
        if self.retry is not None or self.drift is not None:
            conf = decision_confidence(post[:n_real], accepted[:n_real])
        if self.drift is not None:
            self.drift.observe_launch(
                float(np.mean(conf)),
                float(np.mean(accepted[:n_real])) / max(n_bits, 1),
            )
        if self.retry is None:
            for i, (rid, _, _, _) in enumerate(taken):
                out[rid] = (post[i], int(accepted[i]))
                emitted.append(rid)
        else:
            base = self.net.n_bits
            clamped = bool(
                attempt > 0
                and self.retry.n_bits_for(base, attempt)
                < base * self.retry.escalation ** attempt
            )
            for i, (rid, row, _, bits_before) in enumerate(taken):
                total = bits_before + n_bits
                ok = bool(conf[i] >= self.retry.min_confidence)
                if tr is not None and rid in self._retry_spans:
                    # this launch carried the frame's retry attempt: close
                    # the span opened when it was flagged
                    tr.end(self._retry_spans.pop(rid), confidence=float(conf[i]))
                if not ok and attempt < self.retry.max_retries:
                    self._retry_q.append((rid, row, attempt + 1, total))
                    if tr is not None:
                        self._retry_spans[rid] = tr.begin(
                            f"retry[{rid}]", parent=lf.lspan, track="retry",
                            attempt=attempt + 1, confidence=float(conf[i]),
                        )
                    if mx is not None:
                        mx.inc(f"retry_attempt_{attempt + 1}")
                    continue
                out[rid] = (post[i], int(accepted[i]))
                emitted.append(rid)
                self.reports[rid] = FrameReport(
                    confidence=float(conf[i]), attempts=attempt + 1,
                    n_bits=n_bits, total_bits=total, reliable=ok,
                    escalation_clamped=clamped,
                )
                self.stats.record_frame(float(conf[i]), attempt, total, ok)
                if mx is not None and not ok:
                    mx.inc("flagged_unreliable")
                if mx is not None and clamped:
                    mx.inc("escalation_clamped")
        if mx is not None:
            mx.inc("frames_out", len(emitted))
            if lf.t_dispatch is not None:
                mx.observe(
                    "launch_ms", (t_now - lf.t_dispatch) * 1e3,
                    budget_ms=PAPER_BUDGET_MS,
                )
            # one dict pop per frame (C-speed map, single lookup), with
            # the arithmetic vectorised: harvest bookkeeping is on the
            # <=5% overhead budget
            waits = [
                t for t in map(self._t_submit.pop, emitted,
                               itertools.repeat(None))
                if t is not None
            ]
            if waits:
                mx.hist("frame_ms", budget_ms=PAPER_BUDGET_MS).observe_many(
                    (t_now - np.asarray(waits)) * 1e3
                )
        if tr is not None:
            tr.end(lf.hspan, emitted=len(emitted))
            tr.end(lf.lspan, ticket=lf.ticket)

    def _zero_post(self) -> np.ndarray:
        """The flagged-unreliable posterior for a frame no launch could serve."""
        q = self.net.query_cards
        if all(c == 2 for c in q):
            return np.zeros((len(q),), np.float32)
        return np.zeros((len(q), max(q)), np.float32)

    def _recover_launch(
        self, lf: _InFlight, exc: Exception, out: Dict[int, Tuple[np.ndarray, int]]
    ) -> None:
        """Restore bookkeeping for one failed launch (never drops a frame).

        Spans are closed with an ``error`` attr, the failure is recorded in
        ``launch_failures`` / ``stats`` / the metrics registry, and every
        frame of the launch is either re-enqueued at the front of its queue
        (fresh entropy on re-dispatch: the launch counter already advanced)
        or, past its ``max_redispatch`` budget, emitted as a flagged zero
        posterior so the caller still sees exactly one terminal result.
        """
        tr, mx = self.trace, self.metrics
        kind = lf.fault or getattr(exc, "kind", None) or "error"
        if tr is not None:
            for sid in (lf.hspan, lf.dspan, lf.lspan):
                if sid is not None and not tr.get(sid).done:
                    tr.end(sid, error=kind)
        self.launch_failures.append(
            LaunchFailure(
                ticket=lf.ticket, kind=kind,
                rids=tuple(item[0] for item in lf.taken),
                attempt=lf.attempt, error=str(exc),
            )
        )
        self.stats.launch_failures += 1
        if mx is not None:
            mx.inc("launch_failures")
            mx.inc(f"launch_failures_{kind}")
        requeue: list = []
        for item in lf.taken:
            rid = item[0]
            if rid in out:   # paranoia: never double-emit or re-enqueue emitted
                continue
            n_fail = self._fail_counts.get(rid, 0) + 1
            self._fail_counts[rid] = n_fail
            if n_fail <= self.max_redispatch:
                requeue.append(item)
                continue
            # redispatch budget exhausted: graceful degradation, never a drop
            self._fail_counts.pop(rid, None)
            out[rid] = (self._zero_post(), 0)
            self.reports[rid] = FrameReport(
                confidence=0.0, attempts=lf.attempt + 1, n_bits=0,
                total_bits=item[3], reliable=False,
            )
            self.stats.record_frame(0.0, lf.attempt, item[3], False)
            self._t_submit.pop(rid, None)
            if mx is not None:
                mx.inc("frames_out")
                mx.inc("fault_exhausted")
        if requeue:
            if mx is not None:
                mx.inc("redispatched_frames", len(requeue))
            if lf.attempt == 0:
                self._queue.extendleft(
                    (rid, row) for rid, row, _, _ in reversed(requeue)
                )
            else:
                self._retry_q.extendleft(reversed(requeue))

    def step(
        self, key: jax.Array | None = None, block: bool = True
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Run one round of batched launches over the queued frames.

        ``block=True`` (default) harvests immediately and returns
        {rid: (posteriors (n_q,), accepted bit count)} for this round (plus
        any still-unharvested async launches).  ``block=False`` only
        *dispatches* -- the jit launch's device work proceeds asynchronously
        while the caller packs more frames -- and returns ``{}``; collect
        results later with :meth:`harvest`.  ``key=None`` uses the driver's
        own launch-counter key sequence.

        Without a retry policy a round is exactly one launch (one batch off
        the queue).  With one, pending retry batches launch first (one per
        attempt level present, escalated programs), then the main batch; an
        explicit ``key`` covers them all by folding the within-step launch
        index (launch 0 uses ``key`` itself, so the no-retry case is
        unchanged).
        """
        if self.trace is None:
            return self._step_impl(key, block)
        with self.trace.span("step", block=block):
            return self._step_impl(key, block)

    def _step_impl(
        self, key: jax.Array | None, block: bool
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        if not self._queue and not self._retry_q:
            return self.harvest() if block else {}
        n = 0

        def sub():
            nonlocal n
            k = None if key is None else (
                key if n == 0 else jax.random.fold_in(key, n)
            )
            n += 1
            return k

        while self._retry_q:
            self._dispatch_retries(sub())
        if self._queue:
            self._dispatch(sub())
        return self.harvest() if block else {}

    def drain(self, key: jax.Array | None = None) -> Dict[int, Tuple[np.ndarray, int]]:
        """Step until the queue (and any retry backlog) is empty.

        Returns all results keyed by rid.  Any launches previously dispatched
        with ``step(block=False)`` are harvested too, so ``drain`` is always
        the "collect everything" call -- even when the queue itself is
        already empty.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._queue or self._retry_q:
            if key is None:
                sub = None
            else:
                key, sub = jax.random.split(key)
            out.update(self.step(sub))
        out.update(self.harvest())
        return out

    def drain_async(
        self, key: jax.Array | None = None
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Pipeline the whole queue: dispatch every launch, then harvest.

        Each launch is dispatched while its predecessors' device work is
        still in flight; ``block_until_ready`` happens once per harvest
        round, after everything dispatchable is in the air.  Key sequencing
        and rid mapping are identical to :meth:`drain`, so without a retry
        policy the posteriors are bit-identical to the sync path for the same
        ``(base_key, salt)``.  With a retry policy each harvest may re-queue
        under-confidence frames, which pipeline through further rounds until
        none remain; retry-round launch *grouping* differs from ``drain``'s
        (retries batch up across the whole round, and launch keys are drawn
        in a different order), so sync and async posteriors agree only for
        frames that never retried.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._queue or self._retry_q or self._inflight:
            while self._queue or self._retry_q:
                if key is None:
                    sub = None
                else:
                    key, sub = jax.random.split(key)
                self.step(sub, block=False)
            out.update(self.harvest())
        return out
