"""Declarative Bayesian-network specs over binary nodes.

A :class:`NetworkSpec` is the compiler's source language: named binary nodes,
DAG edges, one CPT row per parent assignment, plus the evidence/query sets the
compiled program exposes.  The spec is pure data -- validation happens at
construction, lowering happens in :mod:`repro.bayesnet.compile`, and the exact
oracle in :mod:`repro.bayesnet.analytic` interprets the same spec, so the two
backends can never drift apart structurally.

CPT convention (matches ``core/graph.py``'s Fig S8 ordering): for a node with
parents ``(P0, .., Pm-1)``, ``cpt`` is a flat tuple of ``2**m`` probabilities
``P(node = 1 | parents)``, indexed by the binary number whose MOST significant
bit is ``P0`` -- i.e. for two parents the order is 00, 01, 10, 11.  A root node
has ``parents = ()`` and a length-1 ``cpt`` holding its prior.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Tuple


@dataclasses.dataclass(frozen=True)
class Node:
    """One binary variable: ``cpt[i] = P(node=1 | parent assignment i)``."""

    name: str
    parents: Tuple[str, ...] = ()
    cpt: Tuple[float, ...] = (0.5,)

    def __post_init__(self):
        object.__setattr__(self, "parents", tuple(self.parents))
        object.__setattr__(self, "cpt", tuple(float(p) for p in self.cpt))
        if len(self.cpt) != 1 << len(self.parents):
            raise ValueError(
                f"node {self.name!r}: {len(self.parents)} parents need "
                f"{1 << len(self.parents)} CPT rows, got {len(self.cpt)}"
            )
        for p in self.cpt:
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"node {self.name!r}: CPT entry {p} outside [0, 1]")
        if len(set(self.parents)) != len(self.parents):
            raise ValueError(f"node {self.name!r}: duplicate parent")


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """A validated DAG of :class:`Node` plus evidence/query sets.

    ``evidence``/``queries`` name the observed and posterior-target nodes the
    compiled program is specialised for; both default to empty and can be
    overridden at compile time.
    """

    name: str
    nodes: Tuple[Node, ...]
    evidence: Tuple[str, ...] = ()
    queries: Tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "evidence", tuple(self.evidence))
        object.__setattr__(self, "queries", tuple(self.queries))
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate node names")
        by_name = {n.name: n for n in self.nodes}
        for n in self.nodes:
            for p in n.parents:
                if p not in by_name:
                    raise ValueError(f"{self.name}: {n.name!r} has unknown parent {p!r}")
        for e in self.evidence + self.queries:
            if e not in by_name:
                raise ValueError(f"{self.name}: unknown evidence/query node {e!r}")
        object.__setattr__(self, "_topo", _toposort(by_name))

    # ------------------------------------------------------------- accessors
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def topo_order(self) -> Tuple[str, ...]:
        """Node names, parents always before children."""
        return self._topo

    def index(self, name: str) -> int:
        """Position of ``name`` in the declared node order."""
        for i, n in enumerate(self.nodes):
            if n.name == name:
                return i
        raise KeyError(name)

    def roots(self) -> Tuple[str, ...]:
        return tuple(n.name for n in self.nodes if not n.parents)

    def max_fan_in(self) -> int:
        return max((len(n.parents) for n in self.nodes), default=0)


def _toposort(by_name: Dict[str, Node]) -> Tuple[str, ...]:
    """Kahn's algorithm; raises on cycles."""
    indeg = {name: len(n.parents) for name, n in by_name.items()}
    children: Dict[str, list] = {name: [] for name in by_name}
    for name, n in by_name.items():
        for p in n.parents:
            children[p].append(name)
    ready = sorted(name for name, d in indeg.items() if d == 0)
    order = []
    while ready:
        name = ready.pop(0)
        order.append(name)
        for c in children[name]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
    if len(order) != len(by_name):
        cyc = sorted(name for name, d in indeg.items() if d > 0)
        raise ValueError(f"cycle through nodes {cyc}")
    return tuple(order)


def chain(name: str, probs: Iterable[float], cond: Iterable[Tuple[float, float]]) -> NetworkSpec:
    """Convenience: a Markov chain root -> n1 -> n2 ... (used in tests/benches).

    ``probs`` gives the root prior; ``cond`` gives (P(child|parent=1),
    P(child|parent=0)) per link.
    """
    probs = list(probs)
    nodes = [Node("x0", (), (probs[0],))]
    for i, (p1, p0) in enumerate(cond):
        nodes.append(Node(f"x{i + 1}", (f"x{i}",), (p0, p1)))
    return NetworkSpec(name=name, nodes=tuple(nodes))
