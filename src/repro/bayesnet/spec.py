"""Declarative Bayesian-network specs over cardinality-``k`` nodes.

A :class:`NetworkSpec` is the compiler's source language: named discrete nodes
(each taking values ``0 .. k-1``), DAG edges, one CPT row per parent
assignment, plus the evidence/query sets the compiled program exposes.  The
spec is pure data -- validation happens at construction, lowering happens in
:mod:`repro.bayesnet.compile`, and the exact oracle in
:mod:`repro.bayesnet.analytic` interprets the same spec, so the two backends
can never drift apart structurally.

CPT convention (the mixed-radix generalisation of ``core/graph.py``'s Fig S8
ordering): for a node with parents ``(P0, .., Pm-1)`` of cardinalities
``(k0, .., km-1)``, the CPT has ``k0 * .. * km-1`` rows, indexed by the
mixed-radix number whose MOST significant digit is ``P0`` -- for two binary
parents the order is 00, 01, 10, 11, exactly as before.

Two CPT spellings:

* **flat binary** (the legacy form, unchanged): a tuple of floats, entry ``i``
  = ``P(node = 1 | parent row i)``.  Only valid for ``k = 2`` nodes whose
  parents are all binary; a root holds its prior as a length-1 tuple.
* **nested rows** (the k-ary form): a tuple of rows, each row a length-``k``
  tuple of per-value probabilities summing to 1.  Required whenever the node
  or any parent has ``k > 2``; also accepted for binary nodes as
  ``((P(0|row), P(1|row)), ...)``.

``Node.categorical`` builds a nested-row node with ``k`` inferred from the row
length.  Binary stays the ``k = 2`` special case with unchanged behaviour
everywhere downstream.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Sequence, Tuple

from repro.core.bitops import value_bits  # noqa: F401  (re-exported: spec-level helper)

_ROW_SUM_TOL = 1e-3


@dataclasses.dataclass(frozen=True)
class Node:
    """One discrete variable with ``k`` values (``k = 2``: a classic binary node)."""

    name: str
    parents: Tuple[str, ...] = ()
    cpt: Tuple = (0.5,)
    k: int = 2

    def __post_init__(self):
        object.__setattr__(self, "parents", tuple(self.parents))
        if int(self.k) < 2:
            raise ValueError(f"node {self.name!r}: cardinality k={self.k} < 2")
        object.__setattr__(self, "k", int(self.k))
        if len(set(self.parents)) != len(self.parents):
            raise ValueError(f"node {self.name!r}: duplicate parent")
        cpt = tuple(self.cpt)
        if not cpt:
            raise ValueError(f"node {self.name!r}: empty CPT")
        nested = any(isinstance(row, (tuple, list)) for row in cpt)
        if nested:
            if not all(isinstance(row, (tuple, list)) for row in cpt):
                raise ValueError(
                    f"node {self.name!r}: mixed flat/nested CPT entries"
                )
            rows = []
            for row in cpt:
                row = tuple(float(p) for p in row)
                if len(row) != self.k:
                    raise ValueError(
                        f"node {self.name!r}: CPT row has {len(row)} value "
                        f"probabilities for cardinality k={self.k}"
                    )
                for p in row:
                    if not 0.0 <= p <= 1.0:
                        raise ValueError(
                            f"node {self.name!r}: CPT entry {p} outside [0, 1]"
                        )
                if abs(sum(row) - 1.0) > _ROW_SUM_TOL:
                    raise ValueError(
                        f"node {self.name!r}: CPT row {row} sums to {sum(row)}, "
                        f"not 1"
                    )
                rows.append(row)
            object.__setattr__(self, "cpt", tuple(rows))
        else:
            # Legacy flat-binary form: P(node = 1 | row), binary parents only
            # (row count re-validated against true parent cardinalities by
            # NetworkSpec; here the classic 2**m contract is enforced).
            if self.k != 2:
                raise ValueError(
                    f"node {self.name!r}: flat CPT form is binary-only; "
                    f"k={self.k} needs nested per-value rows"
                )
            cpt = tuple(float(p) for p in cpt)
            if len(cpt) != 1 << len(self.parents):
                raise ValueError(
                    f"node {self.name!r}: {len(self.parents)} parents need "
                    f"{1 << len(self.parents)} CPT rows, got {len(cpt)}"
                )
            for p in cpt:
                if not 0.0 <= p <= 1.0:
                    raise ValueError(f"node {self.name!r}: CPT entry {p} outside [0, 1]")
            object.__setattr__(self, "cpt", cpt)

    # ------------------------------------------------------------- accessors
    @classmethod
    def categorical(
        cls, name: str, parents: Sequence[str], rows: Sequence[Sequence[float]]
    ) -> "Node":
        """Nested-row constructor with ``k`` inferred from the row length."""
        rows = tuple(tuple(float(p) for p in row) for row in rows)
        if not rows:
            raise ValueError(f"node {name!r}: empty CPT")
        return cls(name=name, parents=tuple(parents), cpt=rows, k=len(rows[0]))

    @property
    def is_flat(self) -> bool:
        """True for the legacy flat-binary CPT spelling."""
        return not isinstance(self.cpt[0], tuple)

    def value_probs(self) -> Tuple[Tuple[float, ...], ...]:
        """Canonical per-row per-value probabilities ``((P(0), .., P(k-1)), ..)``."""
        if self.is_flat:
            return tuple((1.0 - p, p) for p in self.cpt)
        return self.cpt

    @property
    def n_value_bits(self) -> int:
        """Packed bit-planes carrying this node's sampled value."""
        return value_bits(self.k)


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """A validated DAG of :class:`Node` plus evidence/query sets.

    ``evidence``/``queries`` name the observed and posterior-target nodes the
    compiled program is specialised for; both default to empty and can be
    overridden at compile time.  Evidence frames carry one integer in
    ``[0, k)`` per evidence node; a query of cardinality ``k`` yields a
    normalised length-``k`` posterior vector downstream.
    """

    name: str
    nodes: Tuple[Node, ...]
    evidence: Tuple[str, ...] = ()
    queries: Tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        object.__setattr__(self, "evidence", tuple(self.evidence))
        object.__setattr__(self, "queries", tuple(self.queries))
        names = [n.name for n in self.nodes]
        if len(set(names)) != len(names):
            raise ValueError(f"{self.name}: duplicate node names")
        by_name = {n.name: n for n in self.nodes}
        for n in self.nodes:
            for p in n.parents:
                if p not in by_name:
                    raise ValueError(f"{self.name}: {n.name!r} has unknown parent {p!r}")
        for e in self.evidence + self.queries:
            if e not in by_name:
                raise ValueError(f"{self.name}: unknown evidence/query node {e!r}")
        # Row counts against the true parent cardinalities (the flat-binary
        # Node check assumes binary parents; this is the authoritative one).
        for n in self.nodes:
            expect = math.prod(by_name[p].k for p in n.parents)
            got = len(n.value_probs())
            if got != expect:
                raise ValueError(
                    f"{self.name}: node {n.name!r} needs {expect} CPT rows for "
                    f"parent cardinalities "
                    f"{tuple(by_name[p].k for p in n.parents)}, got {got}"
                )
        object.__setattr__(self, "_topo", _toposort(by_name))

    # ------------------------------------------------------------- accessors
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def topo_order(self) -> Tuple[str, ...]:
        """Node names, parents always before children."""
        return self._topo

    def index(self, name: str) -> int:
        """Position of ``name`` in the declared node order."""
        for i, n in enumerate(self.nodes):
            if n.name == name:
                return i
        raise KeyError(name)

    def roots(self) -> Tuple[str, ...]:
        return tuple(n.name for n in self.nodes if not n.parents)

    def max_fan_in(self) -> int:
        return max((len(n.parents) for n in self.nodes), default=0)

    def card(self, name: str) -> int:
        """Cardinality of node ``name``."""
        return self.node(name).k

    def cards(self, names: Iterable[str] | None = None) -> Tuple[int, ...]:
        """Cardinalities of ``names`` (default: declared node order)."""
        if names is None:
            return tuple(n.k for n in self.nodes)
        return tuple(self.card(nm) for nm in names)

    def max_card(self) -> int:
        return max(n.k for n in self.nodes)

    def cpt_rows(self, name: str) -> Tuple[Tuple[float, ...], ...]:
        """Canonical per-value CPT rows of ``name`` (mixed-radix row order)."""
        return self.node(name).value_probs()


def _toposort(by_name: Dict[str, Node]) -> Tuple[str, ...]:
    """Kahn's algorithm; raises on cycles."""
    indeg = {name: len(n.parents) for name, n in by_name.items()}
    children: Dict[str, list] = {name: [] for name in by_name}
    for name, n in by_name.items():
        for p in n.parents:
            children[p].append(name)
    ready = sorted(name for name, d in indeg.items() if d == 0)
    order = []
    while ready:
        name = ready.pop(0)
        order.append(name)
        for c in children[name]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
    if len(order) != len(by_name):
        cyc = sorted(name for name, d in indeg.items() if d > 0)
        raise ValueError(f"cycle through nodes {cyc}")
    return tuple(order)


def chain(name: str, probs: Iterable[float], cond: Iterable[Tuple[float, float]]) -> NetworkSpec:
    """Convenience: a Markov chain root -> n1 -> n2 ... (used in tests/benches).

    ``probs`` gives the root prior; ``cond`` gives (P(child|parent=1),
    P(child|parent=0)) per link.
    """
    probs = list(probs)
    nodes = [Node("x0", (), (probs[0],))]
    for i, (p1, p0) in enumerate(cond):
        nodes.append(Node(f"x{i + 1}", (f"x{i}",), (p0, p1)))
    return NetworkSpec(name=name, nodes=tuple(nodes))
