"""Decision reliability: confidence signal, retry policy, harvest statistics.

The paper's claim is *timely reliable* decision-making: the stochastic
readout must not only be fast, it must know when it has not yet accumulated
enough evidence to commit to an action.  This module derives that signal from
quantities every compiled launch already returns -- the posterior count
ratios and the accepted-sample count -- and packages the policy knobs and
bookkeeping the :class:`~repro.bayesnet.driver.FrameDriver` uses to act on
it.

**Confidence.**  For one query, the MAP decision flips iff the runner-up
value out-draws the leader on a re-run.  With ``c1`` / ``c2`` accepted counts
for the top two values, the count margin is asymptotically normal with
variance ~ ``c1 + c2`` (binomial between the two leaders, conditioned on the
rest), so

    z = (c1 - c2) / sqrt(c1 + c2)

is a decision-margin z-score and ``Phi(z)`` approximates the probability the
decision survives a fresh launch.  A frame's confidence is the *minimum* over
its queries (the decision vector is only as reliable as its shakiest entry),
and exactly ``0`` where nothing was accepted -- a rejected frame carries no
evidence at all, whatever the fallback posterior says.

**Retry.**  :class:`RetryPolicy` bounds how hard the driver tries: frames
below ``min_confidence`` are re-launched with fresh entropy and an
``escalation``-times longer bitstream, at most ``max_retries`` times, never
past ``max_n_bits``.  Budget exhaustion degrades gracefully: the frame is
emitted with its best-effort posterior and ``reliable=False`` in its
:class:`FrameReport`, never dropped.

**Accounting.**  :class:`ReliabilityStats` aggregates per-harvest counters
(retries, escalation histogram, slow launches flagged by the driver's
wall-time watchdog, bit budget) so benchmarks can report retry overhead next
to flip-rate; :func:`flip_rate` scores decision stability against a
reference.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

_erf = np.vectorize(math.erf, otypes=[np.float64])

# Terminal frame statuses of the fleet-level serving tier
# (:class:`~repro.serve.router.BayesRouter`): every submitted frame ends in
# EXACTLY one of these -- the never-drop invariant, extended from the frame
# (FrameReport.reliable) to the fleet.
STATUS_OK = "OK"                    # served at full fidelity
STATUS_DEGRADED = "DEGRADED"        # served with a downgraded n_bits plan
STATUS_UNRELIABLE = "UNRELIABLE"    # emitted below confidence / after failures
STATUS_REJECTED = "REJECTED"        # shed at admission: deadline-infeasible
TERMINAL_STATUSES = (
    STATUS_OK, STATUS_DEGRADED, STATUS_UNRELIABLE, STATUS_REJECTED,
)


def _phi(z: np.ndarray) -> np.ndarray:
    """Standard normal CDF, elementwise."""
    return 0.5 * (1.0 + _erf(np.asarray(z, np.float64) / math.sqrt(2.0)))


def top2_margin_z(post: np.ndarray, accepted: np.ndarray) -> np.ndarray:
    """Per-query decision-margin z-scores, shape ``(B, n_q)``.

    ``post`` is a compiled-network posterior batch -- ``(B, n_q)`` of
    ``P(q=1)`` for all-binary queries or ``(B, n_q, max_k)`` normalised
    per-value posteriors -- and ``accepted`` the ``(B,)`` accepted-sample
    counts.  Counts are reconstructed as ``post * accepted`` (the ratio
    estimator's posteriors are exactly count fractions), the top two values
    per query found, and ``z = (c1 - c2) / sqrt(max(c1 + c2, 1))``.
    Rows with ``accepted == 0`` get ``z = 0`` for every query.
    """
    post = np.asarray(post, np.float64)
    acc = np.asarray(accepted, np.float64)
    if post.ndim == 2:                         # binary layout: P(q=1)
        top = np.maximum(post, 1.0 - post) * acc[:, None]
        second = acc[:, None] - top
    else:                                      # k-ary layout: per-value
        counts = post * acc[:, None, None]
        counts = np.sort(counts, axis=-1)
        top, second = counts[..., -1], counts[..., -2]
    z = (top - second) / np.sqrt(np.maximum(top + second, 1.0))
    return np.where(acc[:, None] > 0, z, 0.0)


def decision_confidence(post: np.ndarray, accepted: np.ndarray) -> np.ndarray:
    """Frame-level decision confidence in ``[0, 1]``, shape ``(B,)``.

    ``Phi`` of the *minimum* per-query margin z-score (module docstring);
    exactly ``0.0`` where ``accepted == 0``.
    """
    z = top2_margin_z(post, accepted)
    conf = _phi(np.min(z, axis=-1))
    return np.where(np.asarray(accepted) > 0, conf, 0.0)


def flip_rate(decisions: np.ndarray, reference: np.ndarray) -> float:
    """Fraction of per-query MAP decisions that differ from ``reference``.

    Both arguments are ``(B, n_q)`` integer decision arrays (e.g. from
    :meth:`CompiledNetwork.decide` under different noise / entropy); the
    rate is elementwise over all ``B * n_q`` decisions.
    """
    d = np.asarray(decisions)
    r = np.asarray(reference)
    if d.shape != r.shape:
        raise ValueError(f"decision shapes differ: {d.shape} vs {r.shape}")
    if d.size == 0:
        return 0.0
    return float(np.mean(d != r))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded confidence-gated retry (driver knob, see module docstring).

    ``min_confidence``: emit without retry at or above this.  ``max_retries``:
    re-launch budget per frame (attempts = 1 + retries).  ``escalation``:
    n_bits multiplier per attempt (exponential -- a retry that was noise-bound
    needs materially more evidence, not another coin flip at the same
    length).  ``max_n_bits``: hard ceiling on any single attempt's stream
    length (compile-size guard).
    """

    min_confidence: float = 0.9
    max_retries: int = 2
    escalation: int = 2
    max_n_bits: int = 1 << 17

    def __post_init__(self):
        if not 0.0 <= self.min_confidence <= 1.0:
            raise ValueError(
                f"min_confidence must be in [0, 1], got {self.min_confidence}"
            )
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.escalation < 1:
            raise ValueError(f"escalation must be >= 1, got {self.escalation}")
        if self.max_n_bits < 32 or self.max_n_bits % 32:
            raise ValueError(
                f"max_n_bits must be a positive multiple of 32, got {self.max_n_bits}"
            )

    def n_bits_for(self, base_n_bits: int, attempt: int) -> int:
        """Stream length of attempt ``attempt`` (0-based), capped and 32-aligned."""
        n = min(int(base_n_bits) * self.escalation**int(attempt), self.max_n_bits)
        return max(32, (n // 32) * 32)


@dataclasses.dataclass(frozen=True)
class FrameReport:
    """Per-frame reliability verdict attached by the retrying driver.

    ``attempts`` counts launches this frame rode (1 = no retry); ``n_bits``
    is the final attempt's stream length, ``total_bits`` the sum over all
    attempts (the frame's whole entropy bill).  ``reliable`` is False only
    when the retry budget ran out below ``min_confidence`` -- the posterior
    is still the best-effort final attempt, never dropped.
    """

    confidence: float
    attempts: int
    n_bits: int
    total_bits: int
    reliable: bool


@dataclasses.dataclass
class ReliabilityStats:
    """Mutable per-driver (or per-harvest) reliability accounting.

    ``escalations`` maps final attempt index (0-based) to frames that
    finished there -- ``{0: N}`` means no frame ever retried.  ``merge``
    folds another instance in (shard / multi-driver aggregation).
    """

    frames: int = 0
    launches: int = 0
    retries: int = 0
    unreliable: int = 0
    slow_launches: int = 0
    launch_failures: int = 0
    total_bits: int = 0
    confidence_sum: float = 0.0
    min_confidence: Optional[float] = None
    escalations: Dict[int, int] = dataclasses.field(default_factory=dict)

    def record_frame(
        self, confidence: float, final_attempt: int, total_bits: int, reliable: bool
    ) -> None:
        self.frames += 1
        self.retries += int(final_attempt)
        self.unreliable += int(not reliable)
        self.total_bits += int(total_bits)
        self.confidence_sum += float(confidence)
        self.min_confidence = (
            float(confidence) if self.min_confidence is None
            else min(self.min_confidence, float(confidence))
        )
        self.escalations[int(final_attempt)] = (
            self.escalations.get(int(final_attempt), 0) + 1
        )

    @property
    def mean_confidence(self) -> float:
        return self.confidence_sum / self.frames if self.frames else 0.0

    @property
    def mean_bits(self) -> float:
        """Mean entropy bill per emitted frame (retry overhead axis)."""
        return self.total_bits / self.frames if self.frames else 0.0

    @property
    def retry_rate(self) -> float:
        return self.retries / self.frames if self.frames else 0.0

    def merge(self, other: "ReliabilityStats") -> None:
        self.frames += other.frames
        self.launches += other.launches
        self.retries += other.retries
        self.unreliable += other.unreliable
        self.slow_launches += other.slow_launches
        self.launch_failures += other.launch_failures
        self.total_bits += other.total_bits
        self.confidence_sum += other.confidence_sum
        if other.min_confidence is not None:
            self.min_confidence = (
                other.min_confidence if self.min_confidence is None
                else min(self.min_confidence, other.min_confidence)
            )
        for k, v in other.escalations.items():
            self.escalations[k] = self.escalations.get(k, 0) + v

    def as_dict(self) -> Dict[str, float]:
        """Flat JSON-friendly snapshot for bench emission."""
        return {
            "frames": self.frames,
            "launches": self.launches,
            "retries": self.retries,
            "retry_rate": self.retry_rate,
            "unreliable": self.unreliable,
            "slow_launches": self.slow_launches,
            "launch_failures": self.launch_failures,
            "mean_bits": self.mean_bits,
            "mean_confidence": self.mean_confidence,
            "min_confidence": (
                self.min_confidence if self.min_confidence is not None else 0.0
            ),
            "escalations": {str(k): v for k, v in sorted(self.escalations.items())},
        }
