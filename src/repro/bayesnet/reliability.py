"""Decision reliability: confidence signal, retry policy, harvest statistics.

The paper's claim is *timely reliable* decision-making: the stochastic
readout must not only be fast, it must know when it has not yet accumulated
enough evidence to commit to an action.  This module derives that signal from
quantities every compiled launch already returns -- the posterior count
ratios and the accepted-sample count -- and packages the policy knobs and
bookkeeping the :class:`~repro.bayesnet.driver.FrameDriver` uses to act on
it.

**Confidence.**  For one query, the MAP decision flips iff the runner-up
value out-draws the leader on a re-run.  With ``c1`` / ``c2`` accepted counts
for the top two values, the count margin is asymptotically normal with
variance ~ ``c1 + c2`` (binomial between the two leaders, conditioned on the
rest), so

    z = (c1 - c2) / sqrt(c1 + c2)

is a decision-margin z-score and ``Phi(z)`` approximates the probability the
decision survives a fresh launch.  A frame's confidence is the *minimum* over
its queries (the decision vector is only as reliable as its shakiest entry),
and exactly ``0`` where nothing was accepted -- a rejected frame carries no
evidence at all, whatever the fallback posterior says.

**Retry.**  :class:`RetryPolicy` bounds how hard the driver tries: frames
below ``min_confidence`` are re-launched with fresh entropy and an
``escalation``-times longer bitstream, at most ``max_retries`` times, never
past ``max_n_bits``.  Budget exhaustion degrades gracefully: the frame is
emitted with its best-effort posterior and ``reliable=False`` in its
:class:`FrameReport`, never dropped.

**Accounting.**  :class:`ReliabilityStats` aggregates per-harvest counters
(retries, escalation histogram, slow launches flagged by the driver's
wall-time watchdog, bit budget) so benchmarks can report retry overhead next
to flip-rate; :func:`flip_rate` scores decision stability against a
reference.

**Drift.**  :class:`DriftMonitor` watches the *slow* failure mode the retry
policy cannot see: crossbar read noise growing with endurance wear degrades
every launch a little, so per-frame confidence gating just retries more and
more while the programmed thresholds walk away from the CPTs.  The monitor
runs one :class:`~repro.distributed.fault.CusumDetector` per launch-level
statistic -- mean decision confidence (drop = drift), acceptance rate
(drop), and optionally an externally supplied flip-vs-expected rate (rise)
-- and escalates a health state machine HEALTHY -> DRIFTING ->
RECALIBRATING.  RECALIBRATING latches until :meth:`DriftMonitor.reset`
(after a calibrate-back plan swap, :mod:`repro.bayesnet.calibrate`).  The
whole monitor is a pure function of its observation sequence, so a seeded
chaos replay reproduces every score and state transition exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.distributed.fault import CusumDetector

_erf = np.vectorize(math.erf, otypes=[np.float64])

# Per-tenant crossbar health states (DriftMonitor, DESIGN.md §15): DRIFTING
# de-escalates if the statistics recover; RECALIBRATING latches until a
# calibrate-back swap resets the monitor.
HEALTH_HEALTHY = "HEALTHY"
HEALTH_DRIFTING = "DRIFTING"
HEALTH_RECALIBRATING = "RECALIBRATING"
HEALTH_STATES = (HEALTH_HEALTHY, HEALTH_DRIFTING, HEALTH_RECALIBRATING)

# Terminal frame statuses of the fleet-level serving tier
# (:class:`~repro.serve.router.BayesRouter`): every submitted frame ends in
# EXACTLY one of these -- the never-drop invariant, extended from the frame
# (FrameReport.reliable) to the fleet.
STATUS_OK = "OK"                    # served at full fidelity
STATUS_DEGRADED = "DEGRADED"        # served with a downgraded n_bits plan
STATUS_UNRELIABLE = "UNRELIABLE"    # emitted below confidence / after failures
STATUS_REJECTED = "REJECTED"        # shed at admission: deadline-infeasible
TERMINAL_STATUSES = (
    STATUS_OK, STATUS_DEGRADED, STATUS_UNRELIABLE, STATUS_REJECTED,
)


def _phi(z: np.ndarray) -> np.ndarray:
    """Standard normal CDF, elementwise."""
    return 0.5 * (1.0 + _erf(np.asarray(z, np.float64) / math.sqrt(2.0)))


def top2_margin_z(post: np.ndarray, accepted: np.ndarray) -> np.ndarray:
    """Per-query decision-margin z-scores, shape ``(B, n_q)``.

    ``post`` is a compiled-network posterior batch -- ``(B, n_q)`` of
    ``P(q=1)`` for all-binary queries or ``(B, n_q, max_k)`` normalised
    per-value posteriors -- and ``accepted`` the ``(B,)`` accepted-sample
    counts.  Counts are reconstructed as ``post * accepted`` (the ratio
    estimator's posteriors are exactly count fractions), the top two values
    per query found, and ``z = (c1 - c2) / sqrt(max(c1 + c2, 1))``.
    Rows with ``accepted == 0`` get ``z = 0`` for every query.
    """
    post = np.asarray(post, np.float64)
    acc = np.asarray(accepted, np.float64)
    if post.ndim == 2:                         # binary layout: P(q=1)
        top = np.maximum(post, 1.0 - post) * acc[:, None]
        second = acc[:, None] - top
    else:                                      # k-ary layout: per-value
        counts = post * acc[:, None, None]
        counts = np.sort(counts, axis=-1)
        top, second = counts[..., -1], counts[..., -2]
    z = (top - second) / np.sqrt(np.maximum(top + second, 1.0))
    return np.where(acc[:, None] > 0, z, 0.0)


def decision_confidence(post: np.ndarray, accepted: np.ndarray) -> np.ndarray:
    """Frame-level decision confidence in ``[0, 1]``, shape ``(B,)``.

    ``Phi`` of the *minimum* per-query margin z-score (module docstring);
    exactly ``0.0`` where ``accepted == 0``.
    """
    z = top2_margin_z(post, accepted)
    conf = _phi(np.min(z, axis=-1))
    return np.where(np.asarray(accepted) > 0, conf, 0.0)


def flip_rate(decisions: np.ndarray, reference: np.ndarray) -> float:
    """Fraction of per-query MAP decisions that differ from ``reference``.

    Both arguments are ``(B, n_q)`` integer decision arrays (e.g. from
    :meth:`CompiledNetwork.decide` under different noise / entropy); the
    rate is elementwise over all ``B * n_q`` decisions.
    """
    d = np.asarray(decisions)
    r = np.asarray(reference)
    if d.shape != r.shape:
        raise ValueError(f"decision shapes differ: {d.shape} vs {r.shape}")
    if d.size == 0:
        return 0.0
    return float(np.mean(d != r))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded confidence-gated retry (driver knob, see module docstring).

    ``min_confidence``: emit without retry at or above this.  ``max_retries``:
    re-launch budget per frame (attempts = 1 + retries).  ``escalation``:
    n_bits multiplier per attempt (exponential -- a retry that was noise-bound
    needs materially more evidence, not another coin flip at the same
    length).  ``max_n_bits``: hard ceiling on any single attempt's stream
    length (compile-size guard).
    """

    min_confidence: float = 0.9
    max_retries: int = 2
    escalation: int = 2
    max_n_bits: int = 1 << 17

    def __post_init__(self):
        if not 0.0 <= self.min_confidence <= 1.0:
            raise ValueError(
                f"min_confidence must be in [0, 1], got {self.min_confidence}"
            )
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.escalation < 1:
            raise ValueError(f"escalation must be >= 1, got {self.escalation}")
        if self.max_n_bits < 32 or self.max_n_bits % 32:
            raise ValueError(
                f"max_n_bits must be a positive multiple of 32, got {self.max_n_bits}"
            )

    def n_bits_for(self, base_n_bits: int, attempt: int) -> int:
        """Stream length of attempt ``attempt`` (0-based), capped and 32-aligned."""
        n = min(int(base_n_bits) * self.escalation**int(attempt), self.max_n_bits)
        return max(32, (n // 32) * 32)


@dataclasses.dataclass(frozen=True)
class FrameReport:
    """Per-frame reliability verdict attached by the retrying driver.

    ``attempts`` counts launches this frame rode (1 = no retry); ``n_bits``
    is the final attempt's stream length, ``total_bits`` the sum over all
    attempts (the frame's whole entropy bill).  ``reliable`` is False only
    when the retry budget ran out below ``min_confidence`` -- the posterior
    is still the best-effort final attempt, never dropped.

    ``escalation_clamped`` flags the retry/degradation collision: the frame's
    escalation schedule asked for more bits than its (degraded) driver's
    ceiling allows, so an attempt ran shorter than the policy's nominal
    ladder -- the serve router clamps ``max_n_bits`` to the tenant's current
    rung rather than silently re-inflating a degraded tenant's launch cost.
    """

    confidence: float
    attempts: int
    n_bits: int
    total_bits: int
    reliable: bool
    escalation_clamped: bool = False


@dataclasses.dataclass
class ReliabilityStats:
    """Mutable per-driver (or per-harvest) reliability accounting.

    ``escalations`` maps final attempt index (0-based) to frames that
    finished there -- ``{0: N}`` means no frame ever retried.  ``merge``
    folds another instance in (shard / multi-driver aggregation).
    """

    frames: int = 0
    launches: int = 0
    retries: int = 0
    unreliable: int = 0
    slow_launches: int = 0
    launch_failures: int = 0
    total_bits: int = 0
    confidence_sum: float = 0.0
    min_confidence: Optional[float] = None
    escalations: Dict[int, int] = dataclasses.field(default_factory=dict)

    def record_frame(
        self, confidence: float, final_attempt: int, total_bits: int, reliable: bool
    ) -> None:
        self.frames += 1
        self.retries += int(final_attempt)
        self.unreliable += int(not reliable)
        self.total_bits += int(total_bits)
        self.confidence_sum += float(confidence)
        self.min_confidence = (
            float(confidence) if self.min_confidence is None
            else min(self.min_confidence, float(confidence))
        )
        self.escalations[int(final_attempt)] = (
            self.escalations.get(int(final_attempt), 0) + 1
        )

    @property
    def mean_confidence(self) -> float:
        return self.confidence_sum / self.frames if self.frames else 0.0

    @property
    def mean_bits(self) -> float:
        """Mean entropy bill per emitted frame (retry overhead axis)."""
        return self.total_bits / self.frames if self.frames else 0.0

    @property
    def retry_rate(self) -> float:
        return self.retries / self.frames if self.frames else 0.0

    def merge(self, other: "ReliabilityStats") -> None:
        self.frames += other.frames
        self.launches += other.launches
        self.retries += other.retries
        self.unreliable += other.unreliable
        self.slow_launches += other.slow_launches
        self.launch_failures += other.launch_failures
        self.total_bits += other.total_bits
        self.confidence_sum += other.confidence_sum
        if other.min_confidence is not None:
            self.min_confidence = (
                other.min_confidence if self.min_confidence is None
                else min(self.min_confidence, other.min_confidence)
            )
        for k, v in other.escalations.items():
            self.escalations[k] = self.escalations.get(k, 0) + v

    def as_dict(self) -> Dict[str, float]:
        """Flat JSON-friendly snapshot for bench emission."""
        return {
            "frames": self.frames,
            "launches": self.launches,
            "retries": self.retries,
            "retry_rate": self.retry_rate,
            "unreliable": self.unreliable,
            "slow_launches": self.slow_launches,
            "launch_failures": self.launch_failures,
            "mean_bits": self.mean_bits,
            "mean_confidence": self.mean_confidence,
            "min_confidence": (
                self.min_confidence if self.min_confidence is not None else 0.0
            ),
            "escalations": {str(k): v for k, v in sorted(self.escalations.items())},
        }


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """Knobs of the online drift detector (see module docstring).

    ``warmup`` launches establish each statistic's baseline (a fresh or
    freshly recalibrated array defines "healthy"); ``cusum_k`` is the CUSUM
    slack in baseline sigmas (sub-``k``-sigma wander decays, sustained
    larger shifts accumulate ~linearly); ``drift_h`` / ``recal_h`` are the
    score thresholds for the DRIFTING flag and the RECALIBRATING latch --
    with a sustained ``s``-sigma shift the monitor escalates after roughly
    ``recal_h / (s - cusum_k)`` launches, so the defaults trip on a 2-sigma
    drift within ~5 launches while one noisy launch (bounded score gain)
    never can.
    """

    warmup: int = 8
    cusum_k: float = 0.5
    drift_h: float = 3.0
    recal_h: float = 8.0
    min_std: float = 1e-3
    ewma_alpha: float = 0.2

    def __post_init__(self):
        if self.warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {self.warmup}")
        if self.drift_h <= 0 or self.recal_h < self.drift_h:
            raise ValueError(
                f"need 0 < drift_h <= recal_h, got {self.drift_h}/{self.recal_h}"
            )
        if self.cusum_k < 0:
            raise ValueError(f"cusum_k must be >= 0, got {self.cusum_k}")


#: statistic name -> CUSUM direction (+1 alarms on rise, -1 on drop)
DRIFT_STATISTICS = {"confidence": -1, "accept_rate": -1, "flip": 1}


class DriftMonitor:
    """Online crossbar-health monitor over per-launch statistics.

    One :class:`~repro.distributed.fault.CusumDetector` per statistic of
    :data:`DRIFT_STATISTICS`; the health state is driven by the *peak*
    detector score: ``>= recal_h`` latches RECALIBRATING (cleared only by
    :meth:`reset`, i.e. an actual calibrate-back swap), ``>= drift_h`` flags
    DRIFTING, and a DRIFTING flag de-escalates if the scores decay back
    below ``drift_h``.  The driver feeds ``confidence`` / ``accept_rate``
    per harvested launch (:class:`~repro.bayesnet.driver.FrameDriver`
    ``drift=``); the flip detector is fed by callers that hold a reference
    decision stream (:meth:`observe_flip`), since a live harvest has no
    oracle.  ``metrics`` routes scores/state into a
    :class:`~repro.obs.MetricsRegistry` (gauges ``drift_score_<stat>`` and
    ``drift_state``, counters ``drift_launches`` / ``drift_alarms``),
    prefixed with ``<name>_`` when a tenant name is given.
    """

    def __init__(self, policy: DriftPolicy | None = None, metrics=None,
                 name: str | None = None):
        self.policy = policy if policy is not None else DriftPolicy()
        if not isinstance(self.policy, DriftPolicy):
            raise TypeError(f"policy must be a DriftPolicy, got {type(policy)!r}")
        self.metrics = metrics
        self.name = name
        p = self.policy
        self.detectors = {
            stat: CusumDetector(
                k=p.cusum_k, direction=direction, warmup=p.warmup,
                min_std=p.min_std, alpha=p.ewma_alpha,
            )
            for stat, direction in DRIFT_STATISTICS.items()
        }
        self.state = HEALTH_HEALTHY
        self.launches = 0
        self.alarms = 0
        self.resets = 0

    def _prefix(self, key: str) -> str:
        return f"{self.name}_{key}" if self.name else key

    def observe_launch(
        self, confidence: float, accept_rate: float,
        flip: float | None = None,
    ) -> str:
        """Fold one harvested launch's statistics; returns the health state."""
        self.launches += 1
        self.detectors["confidence"].observe(confidence)
        self.detectors["accept_rate"].observe(accept_rate)
        if flip is not None:
            self.detectors["flip"].observe(flip)
        if self.metrics is not None:
            self.metrics.inc(self._prefix("drift_launches"))
        return self._update_state()

    def observe_flip(self, flip: float) -> str:
        """Fold one flip-vs-expected observation (caller-supplied reference)."""
        self.detectors["flip"].observe(flip)
        return self._update_state()

    def _update_state(self) -> str:
        peak = self.peak_score
        if self.state != HEALTH_RECALIBRATING:
            if peak >= self.policy.recal_h:
                self.state = HEALTH_RECALIBRATING
                self.alarms += 1
            elif peak >= self.policy.drift_h:
                if self.state != HEALTH_DRIFTING:
                    self.alarms += 1
                self.state = HEALTH_DRIFTING
            else:
                self.state = HEALTH_HEALTHY
        if self.metrics is not None:
            for stat, det in self.detectors.items():
                self.metrics.set_gauge(
                    self._prefix(f"drift_score_{stat}"), det.score
                )
            self.metrics.set_gauge(
                self._prefix("drift_state"), float(HEALTH_STATES.index(self.state))
            )
            if self.state != HEALTH_HEALTHY:
                self.metrics.inc(self._prefix("drift_alarms"))
        return self.state

    @property
    def peak_score(self) -> float:
        return max(det.score for det in self.detectors.values())

    def reset(self, full: bool = False) -> None:
        """Clear the RECALIBRATING latch after a calibrate-back plan swap.

        ``full=True`` also discards the warmup baselines, so the freshly
        swapped plan defines a new "healthy" operating point (use when the
        recalibration changes the expected statistics, e.g. a different
        n_bits rung).
        """
        for det in self.detectors.values():
            det.reset(keep_baseline=not full)
        self.state = HEALTH_HEALTHY
        self.resets += 1
        if self.metrics is not None:
            self.metrics.inc(self._prefix("drift_resets"))
            self.metrics.set_gauge(self._prefix("drift_state"), 0.0)

    def as_dict(self) -> Dict[str, float]:
        """Flat JSON-friendly snapshot for bench emission."""
        out = {
            "state": self.state,
            "launches": self.launches,
            "alarms": self.alarms,
            "resets": self.resets,
            "peak_score": self.peak_score,
        }
        for stat, det in self.detectors.items():
            out[f"score_{stat}"] = det.score
            out[f"ewma_{stat}"] = det.ewma if det.ewma is not None else 0.0
        return out
